"""Execution engine vs numpy SQL semantics, incl. randomized tables."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.engine import JoinSpec, Query, col, execute
from repro.engine import operators as ops
from repro.engine.sip import bloom_build, bloom_probe


def make_db(fact, dim=None, block_rows=64):
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=block_rows)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("b"), ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    t = db.begin(direct_to_ros=True)
    db.insert(t, "f", fact)
    if dim is not None:
        db.create_table(TableSchema("d", (
            ColumnDef("k"), ColumnDef("attr"))),
            sort_order=("k",), segment_by=())
        db.insert(t, "d", dim)
    db.commit(t)
    return db


tables = st.integers(50, 400).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.integers(0, 30), min_size=n, max_size=n),
    st.lists(st.integers(0, 10), min_size=n, max_size=n),
    st.lists(st.integers(-100, 100), min_size=n, max_size=n)))


@settings(max_examples=15, deadline=None)
@given(tables)
def test_groupby_matches_numpy(tbl):
    n, a, b, v = tbl
    fact = {"a": np.asarray(a, np.int64), "b": np.asarray(b, np.int64),
            "v": np.asarray(v, np.float64)}
    db = make_db(fact)
    q = Query("f", predicate=col("a") >= 10, group_by="b",
              aggs=(("cnt", "b", "count"), ("s", "v", "sum"),
                    ("mn", "v", "min"), ("mx", "v", "max")))
    out, _ = execute(db, q)
    m = fact["a"] >= 10
    exp_keys = np.unique(fact["b"][m])
    if len(exp_keys) == 0:
        assert len(out.get("b", [])) == 0
        return
    np.testing.assert_array_equal(np.sort(out["b"]), exp_keys)
    for k in exp_keys:
        sel = m & (fact["b"] == k)
        i = np.where(out["b"] == k)[0][0]
        assert out["cnt"][i] == sel.sum()
        assert abs(out["s"][i] - fact["v"][sel].sum()) < 1e-3
        assert out["mn"][i] == fact["v"][sel].min()
        assert out["mx"][i] == fact["v"][sel].max()


def test_scalar_aggregate():
    fact = {"a": np.arange(100), "b": np.zeros(100, np.int64),
            "v": np.ones(100)}
    db = make_db(fact)
    out, _ = execute(db, Query("f", predicate=col("a") < 50,
                               aggs=(("c", "a", "count"),
                                     ("s", "v", "sum"))))
    assert out["c"][0] == 50 and abs(out["s"][0] - 50) < 1e-6


def test_join_inner_vs_numpy():
    rng = np.random.default_rng(3)
    n = 500
    fact = {"a": rng.integers(0, 50, n), "b": rng.integers(0, 5, n),
            "v": rng.normal(size=n)}
    dim = {"k": np.arange(40), "attr": rng.integers(0, 7, 40)}
    db = make_db(fact, dim)
    q = Query("f", join=JoinSpec("d", "a", "k", dim_columns=("attr",)),
              group_by="attr", aggs=(("cnt", "attr", "count"),))
    out, stats = execute(db, q)
    m = fact["a"] < 40  # only keys present in dim join
    attr = np.full(50, -1)
    attr[dim["k"]] = dim["attr"]
    exp = {}
    for x in attr[fact["a"][m]]:
        exp[x] = exp.get(x, 0) + 1
    got = dict(zip(out["attr"].tolist(), out["cnt"].tolist()))
    assert got == exp
    # SIP is gated on a filtering dim predicate (the paper's predictability
    # lesson): without one, no SIP; with one, applied
    assert not stats.sip_applied
    q2 = Query("f", join=JoinSpec("d", "a", "k", dim_columns=("attr",),
                                  dim_predicate=col("attr") < 3),
               group_by="attr", aggs=(("cnt", "attr", "count"),))
    _, stats2 = execute(db, q2)
    assert stats2.sip_applied


def test_order_limit():
    fact = {"a": np.arange(100), "b": np.arange(100) % 10,
            "v": np.arange(100, dtype=np.float64)}
    db = make_db(fact)
    out, _ = execute(db, Query("f", columns=("a", "v"), order_by="v",
                               descending=True, limit=5))
    np.testing.assert_array_equal(out["v"], [99, 98, 97, 96, 95])


def test_sma_pruning_effective():
    fact = {"a": np.sort(np.arange(10_000) % 1000), "b": np.zeros(
        10_000, np.int64), "v": np.ones(10_000)}
    db = make_db(fact, block_rows=64)
    pred = (col("a") >= 100) & (col("a") < 110)
    m = (fact["a"] >= 100) & (fact["a"] < 110)
    # COUNT takes the rle-scalar path: zero decode, exact result
    out, stats = execute(db, Query("f", predicate=pred,
                                   aggs=(("c", "a", "count"),)))
    assert out["c"][0] == m.sum()
    assert stats.groupby_algorithm == "rle-scalar"
    # SUM must decode -> the scan prunes blocks via SMA min/max
    out, stats = execute(db, Query("f", predicate=pred,
                                   aggs=(("s", "v", "sum"),)))
    assert abs(out["s"][0] - fact["v"][m].sum()) < 1e-6
    assert stats.blocks_pruned > 0.5 * stats.blocks_total


def test_bloom_no_false_negatives():
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.choice(10_000, 500, replace=False))
    probe = jnp.asarray(rng.integers(0, 10_000, 2000))
    bits = bloom_build(keys)
    ok = np.asarray(bloom_probe(bits, probe))
    member = np.isin(np.asarray(probe), np.asarray(keys))
    assert ok[member].all()          # no false negatives, ever
    fpr = ok[~member].mean()
    assert fpr < 0.15                # and a sane false-positive rate


def test_analytic_running_sum():
    v = jnp.asarray([1., 2., 3., 4., 5., 6.])
    p = jnp.asarray([0, 0, 0, 1, 1, 2])
    out = np.asarray(ops.analytic_running_sum(v, p))
    np.testing.assert_allclose(out, [1, 3, 6, 4, 9, 6])


def test_groupby_on_deleted_rows(sales_db):
    db, data = sales_db
    t = db.begin()
    db.delete(t, "sales", lambda r: r["cid"] == 4)
    db.commit(t)
    out, _ = execute(db, Query("sales", group_by="cid",
                               aggs=(("c", "cid", "count"),)))
    assert 4 not in out["cid"].tolist()


def test_query_with_node_down(sales_db):
    db, _ = sales_db
    out0, _ = execute(db, Query("sales", group_by="cid",
                                aggs=(("c", "cid", "count"),)))
    db.fail_node(1)
    from repro.planner import plan_query
    q = Query("sales", group_by="cid", aggs=(("c", "cid", "count"),))
    plan = plan_query(db, q)
    # the optimizer replanned: a buddy store serves node 1's segment
    assert any(owner.endswith("_b1") for _, owner in plan.sources)
    out1, _ = execute(db, q, plan=plan)
    np.testing.assert_array_equal(out0["c"], out1["c"])
