"""Block cache + plan-cached fused executor: correctness under reuse,
invalidation (commit/delete/mergeout), LRU budgets; plus the scan
tail-block delete masking and the prepass-avg satellite fixes."""
import numpy as np

from repro.core import (BlockCache, ColumnDef, SQLType, TableSchema)
from repro.core.projection import super_projection
from repro.core.storage import ROSContainer
from repro.engine import Query, col, execute
from repro.engine import operators as ops


# ---------------------------------------------------------------------------
# LRU mechanics (no jax involved: values are opaque)
# ---------------------------------------------------------------------------

def test_lru_evicts_under_byte_budget():
    cache = BlockCache(budget_bytes=1000)
    for cid in range(5):
        assert cache.put(cid, "c", "decoded", f"v{cid}", 300)
    # 5 * 300 > 1000: the two oldest must have been evicted
    assert cache.stats.bytes_in_use <= 1000
    assert cache.stats.evictions == 2
    assert cache.get(0, "c", "decoded") is None
    assert cache.get(1, "c", "decoded") is None
    assert cache.get(4, "c", "decoded") == "v4"


def test_lru_get_refreshes_recency():
    cache = BlockCache(budget_bytes=900)
    for cid in range(3):
        cache.put(cid, "c", "decoded", cid, 300)
    assert cache.get(0, "c", "decoded") == 0     # 0 becomes most-recent
    cache.put(3, "c", "decoded", 3, 300)         # evicts 1, not 0
    assert cache.get(1, "c", "decoded") is None
    assert cache.get(0, "c", "decoded") == 0


def test_oversized_item_never_cached():
    cache = BlockCache(budget_bytes=100)
    assert not cache.put(1, "c", "decoded", "huge", 101)
    assert len(cache) == 0 and cache.stats.bytes_in_use == 0


def test_invalidate_container_drops_all_kinds():
    cache = BlockCache(budget_bytes=10_000)
    cache.put(7, "a", "encoded", 1, 10)
    cache.put(7, "a", "decoded", 2, 10)
    cache.put(7, "b", "decoded", 3, 10)
    cache.put(8, "a", "decoded", 4, 10)
    assert cache.invalidate_container(7) == 3
    assert cache.get(8, "a", "decoded") == 4
    assert cache.stats.bytes_in_use == 10


# ---------------------------------------------------------------------------
# Engine-level: warm results bit-identical, invalidation end to end
# ---------------------------------------------------------------------------

Q_AGG = Query("sales", predicate=col("date") < 1500, group_by="cid",
              aggs=(("s", "price", "sum"), ("c", "cid", "count"),
                    ("m", "price", "max")))
Q_SEL = Query("sales", columns=("sale_id", "date"),
              predicate=col("date") >= 2000)


def _assert_same(a, b):
    assert sorted(a) == sorted(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_warm_results_bit_identical(sales_db):
    db, _ = sales_db
    for q in (Q_AGG, Q_SEL):
        cold, st_cold = execute(db, q)
        warm, st_warm = execute(db, q)
        _assert_same(cold, warm)
        # the warm run must be served from device-resident blocks
        assert st_warm.block_cache_misses == 0
        assert st_warm.block_cache_hits > 0
    # and the aggregate query's fused program came from the plan cache
    _, st3 = execute(db, Q_AGG)
    assert st3.fused and st3.plan_cache == "hit"


def test_insert_commit_serves_fresh_results(sales_db):
    db, data = sales_db
    before, _ = execute(db, Q_AGG)
    t = db.begin()
    db.insert(t, "sales", {
        "sale_id": np.arange(10**6, 10**6 + 50),
        "cid": np.full(50, 3, np.int64),
        "date": np.full(50, 100, np.int64),       # passes date < 1500
        "price": np.full(50, 10.0)})
    db.commit(t)
    after, _ = execute(db, Q_AGG)
    i = int(np.flatnonzero(after["cid"] == 3)[0])
    j = int(np.flatnonzero(before["cid"] == 3)[0])
    assert after["c"][i] == before["c"][j] + 50
    # warm re-run agrees (WOS rows force the general path; still cached ROS)
    again, _ = execute(db, Q_AGG)
    _assert_same(after, again)
    # moveout drains the WOS; new containers, fresh + correct again
    db.run_tuple_mover(force_moveout=True)
    moved, _ = execute(db, Q_AGG)
    i2 = int(np.flatnonzero(moved["cid"] == 3)[0])
    assert moved["c"][i2] == before["c"][j] + 50


def test_delete_invalidates_and_serves_fresh(sales_db):
    db, data = sales_db
    before, _ = execute(db, Q_AGG)
    epoch_before = db.epochs.latest_queryable()
    # containers now cached; delete every row of cid 5 with date < 1500
    cached_cids = {k[0] for k in db.block_cache.keys()}
    t = db.begin()
    db.delete(t, "sales", lambda r: (r["cid"] == 5) & (r["date"] < 1500))
    db.commit(t)
    # the touched containers' entries were evicted eagerly
    touched = set()
    for node in db.nodes:
        for store in node.stores.values():
            touched |= set(store.delete_vectors.keys())
    assert touched & cached_cids
    for k in db.block_cache.keys():
        assert k[0] not in touched, f"stale entry {k} after delete"
    after, _ = execute(db, Q_AGG)
    assert 5 not in after["cid"]
    again, st = execute(db, Q_AGG)
    _assert_same(after, again)
    # historical read still sees the deleted rows (epoch-keyed validity)
    hist, _ = execute(db, Q_AGG, as_of=epoch_before)
    _assert_same(before, hist)


def test_mergeout_invalidates_retired_containers(sales_db):
    db, data = sales_db
    before, _ = execute(db, Q_AGG)           # populate the cache
    cached_before = {k[0] for k in db.block_cache.keys()}
    assert cached_before
    # second wave of rows -> moveout makes same-stratum siblings ->
    # mergeout retires the cached originals
    t = db.begin()
    db.insert(t, "sales", {
        "sale_id": np.arange(2 * 10**6, 2 * 10**6 + 300),
        "cid": np.full(300, 7, np.int64),
        "date": np.full(300, 42, np.int64),   # passes date < 1500
        "price": np.full(300, 5.0)})
    db.commit(t)
    stats = db.run_tuple_mover(force_moveout=True)
    assert stats["mergeouts"] > 0
    live = {c.id for node in db.nodes for store in node.stores.values()
            for c in store.containers}
    # every cached key now refers to a LIVE container only
    for k in db.block_cache.keys():
        assert k[0] in live, f"stale cache entry {k}"
    assert cached_before - live, "mergeout retired cached containers"
    after, _ = execute(db, Q_AGG)
    i = int(np.flatnonzero(after["cid"] == 7)[0])
    old = (np.flatnonzero(before["cid"] == 7), before["c"])
    old_count = int(old[1][old[0][0]]) if old[0].size else 0
    assert after["c"][i] == old_count + 300
    warm, st = execute(db, Q_AGG)
    _assert_same(after, warm)
    assert st.block_cache_misses == 0


def test_small_budget_still_correct(sales_db):
    db, _ = sales_db
    db.block_cache.budget_bytes = 16_384     # far below the working set
    # pin the decode-then-filter path: under "auto" a budget this tight
    # takes the compressed scan, whose packed working set FITS -- no
    # eviction pressure to exercise (that's engine/compressed.py's win,
    # tested in test_packed_exec.py; here we want the LRU machinery)
    db.exec_mode = "decoded"
    try:
        cold, _ = execute(db, Q_AGG)
        warm, st = execute(db, Q_AGG)
    finally:
        db.exec_mode = "auto"
    _assert_same(cold, warm)
    assert db.block_cache.stats.bytes_in_use <= 16_384
    assert db.block_cache.stats.evictions > 0


# ---------------------------------------------------------------------------
# Satellite: deleted-row masking across the padded tail block
# ---------------------------------------------------------------------------

def test_scan_container_tail_block_delete_mask():
    schema = TableSchema("t", (ColumnDef("a"), ColumnDef("b")))
    proj = super_projection(schema, ("a",), ())
    n, br = 150, 64                       # 3 blocks; tail holds 22 rows
    a = np.arange(n, dtype=np.int64)
    b = (a * 3) % 17
    cont = ROSContainer.build(
        proj, {"a": a, "b": b}, np.ones(n, np.int64),
        sql_types={"a": SQLType.INT, "b": SQLType.INT},
        presorted=True, block_rows=br)
    deleted = np.zeros(n, bool)
    deleted[[5, 70, 149]] = True          # head, middle, last tail row
    r = ops.scan_container(cont, ["a", "b"], deleted=deleted)
    valid = np.asarray(r.valid)
    vals = np.asarray(r.columns["a"])
    assert valid.shape[0] == 3 * br       # padded shape
    assert int(valid.sum()) == n - 3      # tail padding AND deletes masked
    np.testing.assert_array_equal(np.sort(vals[valid]),
                                  np.delete(a, [5, 70, 149]))


# ---------------------------------------------------------------------------
# Satellite: prepass avg from combined sum/count partials
# ---------------------------------------------------------------------------

def test_groupby_prepass_avg_matches_dense():
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    n, domain = 1000, 13
    keys = jnp.asarray(rng.integers(0, domain, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    vals = {"v": jnp.asarray(rng.normal(size=n), jnp.float32)}
    aggs = (("avg_v", "v", "avg"), ("sum_v", "v", "sum"))
    got = ops.groupby_prepass(keys, valid, vals, domain, aggs, block=128)
    want = ops.groupby_dense(keys, valid, vals, domain, aggs)
    for k in ("avg_v", "sum_v", "group_count"):
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-5)
