"""Tuple mover invariants: moveout/mergeout preserve the visible multiset,
respect partition/segment boundaries, elide AHM-dead rows, and bound the
number of merges via exponential strata."""
import math

import numpy as np
import pytest

from repro.core import (ColumnDef, SQLType, TableSchema, VerticaDB)
from repro.core.tuple_mover import plan_mergeout, stratum_of


def _tuples(rows):
    cols = sorted(rows)
    return sorted(zip(*[np.asarray(rows[c]).tolist() for c in cols]))


def test_moveout_mergeout_preserve_visible_rows(sales_db):
    db, data = sales_db
    before = _tuples(db.read_table("sales"))
    # several more commits to create many small containers, then merge
    rng = np.random.default_rng(1)
    for i in range(4):
        t = db.begin()
        db.insert(t, "sales", {
            "sale_id": np.arange(5000 + i * 100, 5100 + i * 100),
            "cid": rng.integers(0, 20, 100),
            "date": rng.integers(0, 3000, 100),
            "price": np.round(rng.normal(100, 10, 100), 2)})
        db.commit(t)
        db.run_tuple_mover(force_moveout=True)
    after = _tuples(db.read_table("sales"))
    assert len(after) == len(before) + 400
    assert _tuples(db.read_table("sales", as_of=1)) == before


def test_mergeout_respects_partition_and_segment(sales_db):
    db, _ = sales_db
    db.run_tuple_mover(force_moveout=True)
    for node in db.nodes:
        for store in node.stores.values():
            if not store.proj.is_super or store.proj.buddy_of:
                continue
            for c in store.containers:
                # every container holds exactly one partition key
                if c.partition_key is not None and c.n_rows:
                    dates = c.decode_column("date")
                    assert (dates // 1000 == c.partition_key).all()


def test_ahm_elision():
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=32)
    db.create_table(TableSchema("t", (ColumnDef("k"), ColumnDef("v"))),
                    sort_order=("k",), segment_by=("k",))
    # two loads so every (partition, segment) group has >= 2 containers
    # and a mergeout actually rewrites them
    for lo in (0, 200):
        t = db.begin()
        db.insert(t, "t", {"k": np.arange(lo, lo + 200),
                           "v": np.arange(lo, lo + 200)})
        db.commit(t)
        db.run_tuple_mover(force_moveout=True)
    t = db.begin()
    db.delete(t, "t", lambda r: r["k"] < 50)
    del_epoch = db.commit(t)
    # historical row count before AHM advances
    assert len(db.read_table("t", as_of=del_epoch - 1)["k"]) == 400
    db.epochs.advance_ahm(to_epoch=del_epoch)
    before_phys = sum(c.n_rows for node in db.nodes
                      for c in node.stores["t_super"].containers)
    # a third load makes every group mergeable again; the tuple mover's
    # rewrite elides the AHM-dead rows
    t = db.begin()
    db.insert(t, "t", {"k": np.arange(400, 600),
                       "v": np.arange(400, 600)})
    db.commit(t)
    stats = db.run_tuple_mover(force_moveout=True)
    assert stats["mergeouts"] > 0
    after_phys = sum(c.n_rows for node in db.nodes
                     for c in node.stores["t_super"].containers)
    assert after_phys < before_phys + 200    # elision reclaimed rows
    for node in db.nodes:
        store = node.stores["t_super"]
        for c in store.containers:
            de = store.delete_epochs_of(c)
            # merged containers carry no AHM-dead rows
            assert not ((de > 0) & (de <= db.epochs.ahm)).any()
    assert len(db.read_table("t")["k"]) == 550


def test_strata_merge_bound():
    """Merging >=2 same-stratum containers must land >= one stratum up,
    so each tuple is remerged O(log N) times."""
    db = VerticaDB(n_nodes=1, k_safety=0, block_rows=32)
    db.create_table(TableSchema("t", (ColumnDef("k"),)),
                    sort_order=("k",), segment_by=())
    rng = np.random.default_rng(0)
    merges = 0
    for i in range(16):
        t = db.begin()
        db.insert(t, "t", {"k": rng.integers(0, 10**6, 512)})
        db.commit(t)
        stats = db.run_tuple_mover(force_moveout=True)
        merges += stats["mergeouts"]
    n_total = 16 * 512
    # log2(16 loads) merges per tuple max; generous upper bound on ops
    assert merges <= 16 * math.ceil(math.log2(16) + 1)
    store = db.nodes[0].stores["t_super"]
    assert sum(c.n_rows for c in store.containers) == n_total


def test_drop_partition_is_instant_bulk_delete(sales_db):
    db, data = sales_db
    db.run_tuple_mover(force_moveout=True)
    n_before = len(db.read_table("sales")["date"])
    in_p0 = int((data["date"] // 1000 == 0).sum())
    db.drop_partition("sales", 0)
    rows = db.read_table("sales")
    assert len(rows["date"]) == n_before - in_p0
    assert (rows["date"] // 1000 != 0).all()
