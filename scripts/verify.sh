#!/usr/bin/env bash
# Fast verification gate: tier-1 fast subset + docs tier + segmented
# differential oracle + fixed-seed chaos tier + quick cstore benchmark
# with a perf-regression check against the committed BENCH_cstore.json
# + serving tier (tests + quick closed-loop benchmark gated against the
# committed BENCH_serving.json).
#
# Usage: scripts/verify.sh            (from the repo root)
#
# Fails when (a) any fast-subset test fails, (b) the docs/segmented/chaos
# tiers fail or hang past their per-tier timeout, (c) the benchmark
# errors, or (d) the quick-mode warm total regresses >
# REGRESSION_TOLERANCE x over the previous quick-mode BENCH_cstore.json
# (same n_fact only).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TOL="${REGRESSION_TOLERANCE:-1.6}"
# per-tier wall-clock budgets (coreutils timeout): a wedged collective or
# an injected Hang that slipped past its in-process budget must fail the
# gate loudly, not stall it
T_FAST="${VERIFY_TIMEOUT_FAST:-600}"
T_DOCS="${VERIFY_TIMEOUT_DOCS:-300}"
T_SEG="${VERIFY_TIMEOUT_SEG:-600}"
T_CHAOS="${VERIFY_TIMEOUT_CHAOS:-900}"
T_BENCH="${VERIFY_TIMEOUT_BENCH:-600}"

echo "== tier-1 fast subset =="
timeout "$T_FAST" python -m pytest -q -x -p no:cacheprovider \
    tests/test_engine.py \
    tests/test_logical_frontend.py \
    tests/test_block_cache.py \
    tests/test_encodings.py \
    tests/test_segmentation_sma.py \
    tests/test_segmentation_props.py \
    tests/test_crash_replay_props.py \
    tests/test_locks.py \
    tests/test_faults.py \
    tests/test_serving.py \
    tests/test_kernels_seg_preagg.py \
    tests/test_kernels_bitunpack.py

echo "== compression tier: packed-exec property tests + 20-query oracle =="
# bit-packed storage round-trips and the compressed-domain execution path
# (code-domain predicates, late materialization) must stay byte-identical
# to the decoded scan -- DESIGN.md §9
timeout "$T_FAST" python -m pytest -q -x -p no:cacheprovider \
    tests/test_packed_exec.py

echo "== docs tier: README/DESIGN snippets must run green =="
timeout "$T_DOCS" python scripts/check_docs.py

echo "== segmented differential oracle (8-device CPU mesh) =="
# a separate process: jax locks the device count at backend init, so the
# 8-placeholder-device mesh needs XLA_FLAGS set before the first import
# (test_segmentation_props.py is host-only and already ran in tier-1)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    timeout "$T_SEG" python -m pytest -q -x -p no:cacheprovider \
    tests/test_segmented_exec.py

echo "== chaos tier: seeded fault schedules on the 8-device mesh =="
# fixed seeds pin the exact fault schedule (fully deterministic given the
# seed): every corpus query must match the never-failed oracle or raise a
# typed AvailabilityError -- zero wrong answers
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    REPRO_CHAOS_SEEDS="${REPRO_CHAOS_SEEDS:-11,23}" \
    timeout "$T_CHAOS" python -m pytest -q -x -p no:cacheprovider \
    tests/test_fault_chaos.py

echo "== async serving tier: pipelined dispatch/drain on the 8-device mesh =="
# seeded 50-ticket flood, bulkhead/rate-limit/cost-model properties, and
# the crash-during-drain failover matrix (DESIGN.md §18) -- all schedules
# deterministic (VirtualClock + fixed seeds)
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    timeout "$T_CHAOS" python -m pytest -q -x -p no:cacheprovider \
    tests/test_serving_async.py

echo "== quick cstore benchmark =="
PREV=""
if [ -f BENCH_cstore.json ]; then
    PREV=$(mktemp)
    cp BENCH_cstore.json "$PREV"
fi
timeout "$T_BENCH" python -m benchmarks.run --quick cstore_queries

python - "$PREV" "$TOL" <<'EOF'
import json
import os
import shutil
import sys

prev_path, tol = sys.argv[1], float(sys.argv[2])
cur = json.load(open("BENCH_cstore.json"))
print(f"[verify] warm total {cur['total_warm_s']:.3f}s, "
      f"frontend {cur.get('total_frontend_s', 0)*1e3:.1f}ms, "
      f"speedup vs baseline {cur['total_speedup']:.2f}x")
# compression gate (DESIGN.md §9): packed device bytes must stay well
# under decoded bytes, and the budget-constrained warm total must keep
# beating the decoded-resident baseline at the same cache budget
comp = cur.get("compression") or {}
pr = comp.get("packed_ratio")
cs = comp.get("constrained_cache_speedup")
pr_max = float(os.environ.get("PACKED_RATIO_MAX", "0.7"))
cs_min = float(os.environ.get("CACHE_SPEEDUP_MIN", "1.2"))
if pr is not None:
    print(f"[verify] compression: packed/decoded {pr:.2f} "
          f"(max {pr_max:.2f}), constrained-cache speedup {cs:.2f}x "
          f"(min {cs_min:.2f}x)")
    if pr > pr_max:
        sys.exit(f"[verify] COMPRESSION REGRESSION: packed/decoded byte "
                 f"ratio {pr:.2f} exceeds {pr_max:.2f}")
    if cs is not None and cs < cs_min:
        sys.exit(f"[verify] COMPRESSION REGRESSION: constrained-cache "
                 f"speedup {cs:.2f}x below {cs_min:.2f}x")
if not prev_path:
    print("[verify] no previous BENCH_cstore.json; quick baseline kept")
    sys.exit(0)
# verify.sh is a GATE, not a record-writer: restore the tracked bench
# file (the full benchmarks.run is the explicit way to update it); the
# quick numbers stay in results/bench/results.json
prev = json.load(open(prev_path))
shutil.copy(prev_path, "BENCH_cstore.json")
if not (prev.get("quick") and cur.get("quick")
        and prev.get("n_fact") == cur.get("n_fact")):
    print("[verify] previous bench not comparable (size/mode); skipping "
          "regression check")
    sys.exit(0)
ratio = cur["total_warm_s"] / max(prev["total_warm_s"], 1e-9)
print(f"[verify] warm total vs previous: {ratio:.2f}x "
      f"(tolerance {tol:.2f}x)")
if ratio > tol:
    sys.exit(f"[verify] PERF REGRESSION: warm total {ratio:.2f}x slower "
             f"than previous run (> {tol:.2f}x)")
# segmented-vs-single-node gate: the device-resident slab path must not
# slide back toward host round-trips (ratio is mesh-size-normalized --
# both runs are 1-shard quick mode here)
sp = cur.get("segmented", {}).get("speedup_vs_single_node")
pp = prev.get("segmented", {}).get("speedup_vs_single_node")
if sp is not None:
    print(f"[verify] segmented speedup vs single-node: {sp:.2f}x"
          + (f" (previous {pp:.2f}x)" if pp is not None else ""))
    if pp is not None and sp < pp / tol:
        sys.exit(f"[verify] PERF REGRESSION: segmented ratio {sp:.2f}x "
                 f"fell below previous {pp:.2f}x / {tol:.2f}")
EOF

echo "== quick serving benchmark =="
PREV_SRV=""
if [ -f BENCH_serving.json ]; then
    PREV_SRV=$(mktemp)
    cp BENCH_serving.json "$PREV_SRV"
fi
timeout "$T_BENCH" python -m benchmarks.run --quick serving

python - "$PREV_SRV" "$TOL" <<'EOF'
import json
import shutil
import sys

prev_path, tol = sys.argv[1], float(sys.argv[2])
cur = json.load(open("BENCH_serving.json"))
# the serving tier's hard requirements: tail latency reported, the
# shared-scan path actually coalescing (a hit rate of 0 means every
# query ran solo -- the subsystem's point is gone), and the pipelined
# core actually parking flights (async_units of 0 means every unit ran
# synchronously -- DESIGN.md §18's point is gone)
assert cur.get("p99_ms"), "serving bench missing p99 latency"
assert cur.get("shared_scan_hit_rate", 0) > 0, \
    "serving bench: shared-scan hit rate is 0"
assert cur.get("async_units", 0) > 0, \
    "serving bench: nothing dispatched asynchronously"
print(f"[verify] serving p50 {cur['p50_ms']:.1f}ms "
      f"p99 {cur['p99_ms']:.1f}ms, {cur['throughput_qps']} qps, "
      f"shared-scan hit rate {cur['shared_scan_hit_rate']:.0%}, "
      f"speedup vs serial {cur['speedup_vs_serial']:.2f}x")
# interactive isolation gate: probe p99 under a bulkheaded batch flood
# must stay within FLOOD_RATIO_MAX x its unloaded p99
import os
fr = cur.get("interactive_p99_flood_ratio")
fr_max = float(os.environ.get("FLOOD_RATIO_MAX", "1.5"))
if fr is not None:
    print(f"[verify] interactive p99 flood ratio {fr:.2f}x "
          f"(max {fr_max:.2f}x)")
    if fr > fr_max:
        sys.exit(f"[verify] ISOLATION REGRESSION: interactive p99 under "
                 f"batch flood is {fr:.2f}x unloaded (> {fr_max:.2f}x)")
if not prev_path:
    print("[verify] no previous BENCH_serving.json; quick baseline kept")
    sys.exit(0)
prev = json.load(open(prev_path))
shutil.copy(prev_path, "BENCH_serving.json")
if not (prev.get("quick") and cur.get("quick")
        and prev.get("n_fact") == cur.get("n_fact")):
    print("[verify] previous serving bench not comparable (size/mode); "
          "skipping regression check")
    sys.exit(0)
ratio = prev["throughput_qps"] / max(cur["throughput_qps"], 1e-9)
print(f"[verify] serving throughput vs previous: {ratio:.2f}x slower "
      f"(tolerance {tol:.2f}x)")
if ratio > tol:
    sys.exit(f"[verify] PERF REGRESSION: serving throughput {ratio:.2f}x "
             f"below previous run (> {tol:.2f}x)")
EOF
echo "== verify OK =="
