#!/usr/bin/env python
"""Docs-as-tests: extract fenced ``python`` code blocks from the repo's
markdown docs and execute them, doctest-style.

Every ```python block in README.md / DESIGN.md runs, in order, in one
shared namespace per file (so a quickstart can build state across
blocks).  A failure prints the offending file, block number and source
line, then exits nonzero -- scripts/verify.sh runs this as its docs
tier, so a quickstart snippet can never rot out from under the README.

Blocks fenced as anything other than ``python`` (```text, ```bash, bare
```) are documentation-only and skipped.

Usage: python scripts/check_docs.py [files...]   (default: README.md DESIGN.md)
"""
from __future__ import annotations

import pathlib
import re
import sys
import time
import traceback

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_FILES = ("README.md", "DESIGN.md")

FENCE_RE = re.compile(
    r"^```python[ \t]*\n(.*?)^```[ \t]*$", re.MULTILINE | re.DOTALL)


def blocks_of(path: pathlib.Path):
    """[(start_line, source), ...] for every ```python fence."""
    text = path.read_text()
    out = []
    for m in FENCE_RE.finditer(text):
        start_line = text[:m.start()].count("\n") + 2  # first code line
        out.append((start_line, m.group(1)))
    return out


def run_file(path: pathlib.Path) -> int:
    blocks = blocks_of(path)
    if not blocks:
        print(f"[check_docs] {path.name}: no python blocks")
        return 0
    ns = {"__name__": f"__docs_{path.stem}__", "__file__": str(path)}
    for i, (line, src) in enumerate(blocks, 1):
        t0 = time.time()
        # compile with a filename that points back at the markdown so
        # tracebacks are clickable; pad so line numbers match the doc
        code = compile("\n" * (line - 1) + src, str(path), "exec")
        try:
            exec(code, ns)
        except Exception:
            print(f"[check_docs] FAIL {path.name} block {i} "
                  f"(line {line}):", file=sys.stderr)
            traceback.print_exc()
            return 1
        print(f"[check_docs] ok   {path.name} block {i} "
              f"(line {line}, {time.time() - t0:.1f}s)")
    return 0


def main(argv) -> int:
    sys.path.insert(0, str(REPO / "src"))
    files = argv[1:] or DEFAULT_FILES
    rc = 0
    for f in files:
        rc |= run_file(REPO / f)
    if rc == 0:
        print("[check_docs] all doc snippets green")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
