"""Distribution planning (paper §3.6/§6.2): the optimizer's co-located /
broadcast / resegment decisions and their modeled network costs on three
physical designs of the same join -- plus a shard_map resegmentation
round-trip (the Send/Recv operator) validated on the host mesh."""
from __future__ import annotations

import sys
import time
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (ColumnDef, SQLType, SegmentationSpec,  # noqa: E402
                        TableSchema, VerticaDB)
from repro.core.projection import ProjectionDef  # noqa: E402
from repro.data.synth import star_schema  # noqa: E402
from repro.engine import LogicalJoin, LogicalQuery, col  # noqa: E402
from repro.engine.exchange import resegment  # noqa: E402
from repro.planner import plan_query  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402


def _db_variant(seg_dim_replicated: bool, fact_seg_on_key: bool):
    fact, dim = star_schema(100_000, 5_000)
    db = VerticaDB(n_nodes=4, k_safety=0, block_rows=4096)
    db.create_table(TableSchema("lineitem", (
        ColumnDef("l_orderkey"), ColumnDef("l_suppkey"),
        ColumnDef("l_shipdate"), ColumnDef("l_qty"),
        ColumnDef("l_extprice", SQLType.FLOAT))),
        sort_order=("l_shipdate",),
        segment_by=("l_orderkey",) if fact_seg_on_key else ("l_suppkey",))
    db.create_table(TableSchema("orders", (
        ColumnDef("o_orderkey"), ColumnDef("o_custkey"),
        ColumnDef("o_orderdate"))),
        sort_order=("o_orderkey",),
        segment_by=() if seg_dim_replicated else ("o_orderkey",))
    t = db.begin(direct_to_ros=True)
    db.insert(t, "lineitem", fact)
    db.insert(t, "orders", dim)
    db.commit(t)
    return db


def run(report):
    q = LogicalQuery(
        "lineitem",
        joins=(LogicalJoin("orders", "l_orderkey", "o_orderkey",
                           dim_columns=("o_custkey",)),),
        group_by=("o_custkey",), aggs=(("c", "*", "count"),))
    decisions = {}
    expected = {"replicated_dim": "co-located",
                "segmented_dim_fact_on_key": "co-located",
                "segmented_dim_fact_off_key": "broadcast"}
    for name, (repl, on_key) in {
        "replicated_dim": (True, True),
        "segmented_dim_fact_on_key": (False, True),
        "segmented_dim_fact_off_key": (False, False),
    }.items():
        db = _db_variant(repl, on_key)
        plan = plan_query(db, q)
        decisions[name] = {"strategy": plan.join_strategy,
                           "net_s": plan.estimated.net_s}
        assert plan.join_strategy.startswith(expected[name]), \
            (name, plan.join_strategy)
        print(f"[distribution] {name}: {plan.join_strategy} "
              f"(net {plan.estimated.net_s*1e3:.3f}ms)")

    # Send/Recv: resegment rows by hash on the host mesh (1 device on CPU
    # CI; N devices on a pod) -- every tuple lands on its hash shard once
    mesh = make_host_mesh(data=jax.device_count(), model=1)
    n = 4096
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    dest = keys % mesh.shape["data"]
    out, valid, overflow = resegment(mesh, "data", {"k": keys, "v": vals},
                                     dest, capacity=2 * n)
    assert int(np.asarray(overflow).sum()) == 0
    kept = np.asarray(out["k"])[np.asarray(valid)]
    assert sorted(kept.tolist()) == sorted(np.asarray(keys).tolist())
    print(f"[distribution] resegment round-trip ok on "
          f"{mesh.shape['data']} shard(s): {len(kept)}/{n} rows")
    report("distribution/decisions",
           {"decisions": decisions, "resegment_rows": int(len(kept))})


if __name__ == "__main__":
    run(lambda k, v: None)
