"""Benchmark driver: one module per paper table/claim.

  compression       -> Table 4 (1M ints + meter data)
  cstore_queries    -> Table 3 (7-query workload, 2 execution models)
  encoded_exec      -> §6.1 operate-on-encoded-data ablation
  tuple_mover_bench -> §4 ingest/merge behaviour
  distribution      -> §3.6/§6.2 join locality decisions + Send/Recv
  serving           -> §7 concurrent serving: closed-loop latency/qps
  roofline          -> §Roofline reader over results/dryrun/

Writes results/bench/results.json and prints a summary per benchmark.
After a cstore_queries run, also writes repo-root BENCH_cstore.json (the
headline perf numbers: cold/warm totals, speedups, disk ratio) so the
perf trajectory is tracked PR-over-PR; a serving run likewise writes
BENCH_serving.json (p50/p95/p99, throughput, shared-scan hit rate).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [name ...]
  --quick: CI-smoke sizes (small N_FACT) via REPRO_BENCH_QUICK=1
"""
import json
import os
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "results" / "bench"


def main() -> None:
    from benchmarks import (compression, cstore_queries, distribution,
                            encoded_exec, roofline, serving,
                            tuple_mover_bench)
    mods = {
        "compression": compression,
        "cstore_queries": cstore_queries,
        "encoded_exec": encoded_exec,
        "tuple_mover_bench": tuple_mover_bench,
        "distribution": distribution,
        "serving": serving,
        "roofline": roofline,
    }
    args = sys.argv[1:]
    if "--quick" in args:
        os.environ["REPRO_BENCH_QUICK"] = "1"
        args = [a for a in args if a != "--quick"]
    names = args or list(mods)
    unknown = [n for n in names if n not in mods]
    if unknown:
        sys.exit(f"[run] unknown benchmark(s) {unknown}; "
                 f"available: {', '.join(mods)}")
    OUT.mkdir(parents=True, exist_ok=True)
    results = {}
    prev = OUT / "results.json"
    if prev.exists():  # merge: partial runs must not clobber other tables
        results.update(json.loads(prev.read_text()))

    def report(key, value):
        results[key] = value

    for name in names:
        print(f"===== {name} =====", flush=True)
        t0 = time.time()
        mods[name].run(report)
        print(f"===== {name} done in {time.time()-t0:.1f}s =====",
              flush=True)
    (OUT / "results.json").write_text(json.dumps(results, indent=1,
                                                 default=str))
    print(f"[run] wrote {OUT/'results.json'}")
    t3 = results.get("cstore_queries/table3")
    if t3 is not None and "cstore_queries" in names:
        bench = {k: t3.get(k) for k in (
            "n_fact", "quick", "total_vertica_s", "total_baseline_s",
            "total_speedup", "total_cold_s", "total_warm_s",
            "warm_speedup_vs_cold", "total_frontend_s", "disk_ratio",
            "segmented", "failover", "compression")}
        bench["frontend_ms_per_query"] = {
            name: row.get("frontend_ms")
            for name, row in t3.get("queries", {}).items()}
        (ROOT / "BENCH_cstore.json").write_text(
            json.dumps(bench, indent=1) + "\n")
        print(f"[run] wrote {ROOT/'BENCH_cstore.json'}")
    srv = results.get("serving/closed_loop")
    if srv is not None and "serving" in names:
        (ROOT / "BENCH_serving.json").write_text(
            json.dumps(srv, indent=1) + "\n")
        print(f"[run] wrote {ROOT/'BENCH_serving.json'}")


if __name__ == '__main__':
    main()
