"""Benchmark driver: one module per paper table/claim.

  compression       -> Table 4 (1M ints + meter data)
  cstore_queries    -> Table 3 (7-query workload, 2 execution models)
  encoded_exec      -> §6.1 operate-on-encoded-data ablation
  tuple_mover_bench -> §4 ingest/merge behaviour
  distribution      -> §3.6/§6.2 join locality decisions + Send/Recv
  roofline          -> §Roofline reader over results/dryrun/

Writes results/bench/<name>.json and prints a summary per benchmark.
Usage: PYTHONPATH=src python -m benchmarks.run [name ...]
"""
import json
import pathlib
import sys
import time

OUT = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def main() -> None:
    from benchmarks import (compression, cstore_queries, distribution,
                            encoded_exec, roofline, tuple_mover_bench)
    mods = {
        "compression": compression,
        "cstore_queries": cstore_queries,
        "encoded_exec": encoded_exec,
        "tuple_mover_bench": tuple_mover_bench,
        "distribution": distribution,
        "roofline": roofline,
    }
    names = sys.argv[1:] or list(mods)
    OUT.mkdir(parents=True, exist_ok=True)
    results = {}
    prev = OUT / "results.json"
    if prev.exists():  # merge: partial runs must not clobber other tables
        results.update(json.loads(prev.read_text()))

    def report(key, value):
        results[key] = value

    for name in names:
        print(f"===== {name} =====", flush=True)
        t0 = time.time()
        mods[name].run(report)
        print(f"===== {name} done in {time.time()-t0:.1f}s =====",
              flush=True)
    (OUT / "results.json").write_text(json.dumps(results, indent=1,
                                                 default=str))
    print(f"[run] wrote {OUT/'results.json'}")


if __name__ == '__main__':
    main()
