"""Encoded-execution ablation (the §6.1 'operate directly on encoded data'
claim): the same filtered aggregate three ways --

  rle-direct : aggregate straight from (value, run_length) pairs
  decode+agg : decode the RLE column, then aggregate
  plain      : unencoded column scan + aggregate

Also reports the HBM-bytes model per variant: the roofline story is that
encoded execution divides the memory term by the compression ratio.
"""
from __future__ import annotations

import sys
import time
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.encodings import Encoding, decode_jnp, encode  # noqa: E402
from repro.core.types import SQLType  # noqa: E402

N = 8_000_000
CARD = 64  # low-cardinality sorted column: RLE's home turf


def _time(fn, reps=5):
    out = fn()
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        jax.block_until_ready(out)
        ts.append(time.time() - t0)
    return min(ts)


def run(report):
    rng = np.random.default_rng(0)
    v = np.sort(rng.integers(0, CARD, N)).astype(np.int64)
    colenc = encode(v, SQLType.INT, Encoding.RLE, block_rows=1 << 14)
    rv = jnp.asarray(colenc.arrays["run_values"], jnp.float32)
    rl = jnp.asarray(colenc.arrays["run_lengths"], jnp.float32)
    plain = jnp.asarray(v, jnp.float32)
    lo, hi = 10.0, 40.0

    @jax.jit
    def agg_rle(rv, rl):
        m = ((rv >= lo) & (rv <= hi) & (rl > 0)).astype(jnp.float32)
        return (rl * m).sum(), (rv * rl * m).sum()

    @jax.jit
    def agg_decoded(col_blocks):
        flat = col_blocks.reshape(-1)[:N]
        m = ((flat >= lo) & (flat <= hi)).astype(jnp.float32)
        return m.sum(), (flat * m).sum()

    @jax.jit
    def agg_plain(flat):
        m = ((flat >= lo) & (flat <= hi)).astype(jnp.float32)
        return m.sum(), (flat * m).sum()

    decoded = decode_jnp(colenc).astype(jnp.float32)

    t_rle = _time(lambda: agg_rle(rv, rl))
    t_dec = _time(lambda: agg_decoded(decoded))
    t_plain = _time(lambda: agg_plain(plain))

    # correctness cross-check
    c1, s1 = agg_rle(rv, rl)
    c3, s3 = agg_plain(plain)
    assert abs(float(c1) - float(c3)) < 1,  (float(c1), float(c3))

    bytes_rle = rv.size * 4 * 2
    bytes_plain = N * 4
    result = {
        "n_rows": N, "cardinality": CARD,
        "runs": int(np.asarray(colenc.arrays["n_runs"]).sum()),
        "ms": {"rle_direct": t_rle * 1e3, "decode_then_agg": t_dec * 1e3,
               "plain": t_plain * 1e3},
        "speedup_vs_plain": {"rle_direct": t_plain / t_rle,
                             "decode_then_agg": t_plain / t_dec},
        "hbm_bytes": {"rle_direct": bytes_rle, "plain": bytes_plain,
                      "reduction": bytes_plain / bytes_rle},
    }
    print(f"[encoded_exec] rle-direct {t_rle*1e3:.2f}ms | decode+agg "
          f"{t_dec*1e3:.2f}ms | plain {t_plain*1e3:.2f}ms "
          f"-> {t_plain/t_rle:.0f}x; bytes reduction "
          f"{bytes_plain/bytes_rle:.0f}x")
    report("encoded_exec/ablation", result)

    # --- grouped variant (Q2/Q3 shape): per-key COUNT/SUM on runs vs on
    # decoded rows -- the grouped twin of kernels/rle_scan_agg.py ---
    @jax.jit
    def grouped_rle(rv, rl):
        k = jnp.clip(rv.astype(jnp.int32), 0, CARD - 1)
        m = (rl > 0).astype(jnp.float32)
        cnt = jnp.zeros(CARD, jnp.float32).at[k.reshape(-1)].add(
            (rl * m).reshape(-1))
        s = jnp.zeros(CARD, jnp.float32).at[k.reshape(-1)].add(
            (rv * rl * m).reshape(-1))
        return cnt, s

    @jax.jit
    def grouped_plain(flat):
        k = jnp.clip(flat.astype(jnp.int32), 0, CARD - 1)
        cnt = jnp.zeros(CARD, jnp.float32).at[k].add(1.0)
        s = jnp.zeros(CARD, jnp.float32).at[k].add(flat)
        return cnt, s

    tg_rle = _time(lambda: grouped_rle(rv, rl))
    tg_plain = _time(lambda: grouped_plain(plain))
    gc1, gs1 = grouped_rle(rv, rl)
    gc2, gs2 = grouped_plain(plain)
    # tail-block padding repeats the last value: counted on the runs side
    # only (the engine subtracts it per container; see pipeline._rle_groupby)
    pad = colenc.n_blocks * colenc.block_rows - N
    assert abs(float(gc1.sum()) - float(gc2.sum()) - pad) < 1
    grouped = {
        "ms": {"rle_grouped": tg_rle * 1e3, "plain_grouped": tg_plain * 1e3},
        "speedup_vs_plain": tg_plain / tg_rle,
    }
    print(f"[encoded_exec] grouped: rle {tg_rle*1e3:.2f}ms | plain "
          f"{tg_plain*1e3:.2f}ms -> {tg_plain/tg_rle:.0f}x")
    report("encoded_exec/grouped", grouped)


if __name__ == "__main__":
    run(lambda k, v: None)
