"""Roofline table reader: aggregates results/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (all three terms per cell, dominant
bottleneck, MODEL_FLOPS ratio, and the derived roofline fraction)."""
from __future__ import annotations

import glob
import json
import pathlib
import sys
from typing import Dict, List

sys.path.insert(0, "src")

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = "baseline") -> List[Dict]:
    rows = []
    for f in sorted(RESULTS.glob(f"*--{tag}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def table(tag: str = "baseline", multi_pod: bool = False) -> str:
    rows = [r for r in load(tag) if r["multi_pod"] == multi_pod]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    mesh = "2x16x16 (512)" if multi_pod else "16x16 (256)"
    out = [f"### Mesh {mesh}, tag `{tag}`", "",
           "| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio | roofline frac | status |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - "
                       f"| - | {r['status']}: "
                       f"{r.get('reason', r.get('error', ''))[:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | ok |")
    return "\n".join(out)


def summary(tag: str = "baseline") -> Dict:
    rows = load(tag)
    ok = [r for r in rows if r["status"] == "ok"]
    return {
        "cells_ok": len(ok),
        "cells_skipped": sum(r["status"] == "skipped" for r in rows),
        "cells_error": sum(r["status"] == "error" for r in rows),
        "dominant_counts": {
            d: sum(r["dominant"] == d for r in ok)
            for d in ("compute", "memory", "collective")},
        "worst_fraction": min(
            (r for r in ok if not r["multi_pod"]),
            key=lambda r: r["roofline_fraction"], default=None) and
        min((f"{r['arch']}/{r['shape']}", r["roofline_fraction"])
            for r in ok if not r["multi_pod"]
            ) if ok else None,
    }


def run(report):
    s = summary()
    print(f"[roofline] cells ok={s['cells_ok']} "
          f"skipped={s['cells_skipped']} error={s['cells_error']} "
          f"dominant={s['dominant_counts']}")
    report("roofline/summary", s)


if __name__ == "__main__":
    print(table(multi_pod=False))
    print()
    print(table(multi_pod=True))
