"""Table 3 reproduction: the C-Store 7-query workload harness (paper §8.1).

The original compared the Vertica product against the C-Store prototype on
a Pentium 4. We reproduce the *harness and the architectural claim*: the
same 7 queries over the same star schema, executed two ways on identical
hardware --

  vertica  : our engine (encoded containers, SMA pruning, SIP, planner)
  baseline : a C-Store-prototype-era execution model -- full uncompressed
             column scans, no block pruning, no SIP, sort-based groupby
             (the prototype had a minimal optimizer and no block index)

The paper's claim is ~2x total (Vertica 9.6s vs C-Store 18.7s) plus ~2x
disk (949MB vs 1987MB); we report our two modes in the same table shape.

Queries are authored through the fluent builder (engine/builder.py) and
lowered to the logical-plan IR once; the harness additionally times the
front-end itself (builder lowering + planning) per query so the API
layer's overhead is tracked PR-over-PR in BENCH_cstore.json.

Query set (reconstructed from the C-Store paper's workload structure:
date-filtered counts/aggregates, groupbys, and fact-dim joins):
  Q1 count where shipdate = D
  Q2 count by suppkey where shipdate = D
  Q3 sum(qty) by suppkey where D1 < shipdate < D2
  Q4 count by shipdate (full scan, sorted-key aggregation)
  Q5 join: sum(extprice) by o_custkey where o_orderdate < D
  Q6 avg(extprice) by suppkey where shipdate > D
  Q7 join: count by o_custkey where suppkey < S (SIP filter path)
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import (ColumnDef, CrashNode, Encoding,  # noqa: E402
                        SQLType, TableSchema, VerticaDB)
from repro.core.projection import super_projection  # noqa: E402
from repro.data.synth import star_schema  # noqa: E402
from repro.engine import LogicalQuery, col, execute  # noqa: E402

N_FACT = 2_000_000
N_DIM = 50_000
# --quick (benchmarks/run.py) / REPRO_BENCH_QUICK=1: CI-smoke-sized run
QUICK_N_FACT = 200_000
QUICK_N_DIM = 5_000


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "") == "1"


def build_db(n_fact=N_FACT, n_dim=N_DIM) -> VerticaDB:
    fact, dim = star_schema(n_fact, n_dim)
    db = VerticaDB(n_nodes=4, k_safety=0, block_rows=4096)
    schema = TableSchema("lineitem", (
        ColumnDef("l_orderkey"), ColumnDef("l_suppkey"),
        ColumnDef("l_shipdate"), ColumnDef("l_qty"),
        ColumnDef("l_extprice", SQLType.FLOAT)))
    db.catalog.add_table(schema)
    # the DBD's storage-optimization choice: RLE on the sorted leader
    db.create_projection(super_projection(
        schema, ("l_shipdate", "l_suppkey"), ("l_orderkey",),
        encodings={"l_shipdate": Encoding.RLE}))
    db.create_table(TableSchema("orders", (
        ColumnDef("o_orderkey"), ColumnDef("o_custkey"),
        ColumnDef("o_orderdate"))),
        sort_order=("o_orderkey",), segment_by=())
    t = db.begin(direct_to_ros=True)
    db.insert(t, "lineitem", fact)
    db.insert(t, "orders", dim)
    db.commit(t)
    return db


def make_builders(db: VerticaDB) -> Dict[str, object]:
    """The 7-query workload, authored with the fluent front-end."""
    li = db.query("lineitem")
    return {
        "Q1": li.where(col("l_shipdate") == 180)
                .agg(c=("*", "count")),
        "Q2": li.where(col("l_shipdate") == 180)
                .group_by("l_suppkey").agg(c=("*", "count")),
        "Q3": li.where((col("l_shipdate") > 60) & (col("l_shipdate") < 120))
                .group_by("l_suppkey").agg(s=("l_qty", "sum")),
        "Q4": li.group_by("l_shipdate").agg(c=("*", "count")),
        "Q5": li.join("orders", on=("l_orderkey", "o_orderkey"),
                      cols=("o_custkey",),
                      where=col("o_orderdate") < 60)
                .group_by("o_custkey").agg(s=("l_extprice", "sum")),
        "Q6": li.where(col("l_shipdate") > 300)
                .group_by("l_suppkey").agg(a=("l_extprice", "avg")),
        "Q7": li.where(col("l_suppkey") < 10)
                .join("orders", on=("l_orderkey", "o_orderkey"),
                      cols=("o_custkey",))
                .group_by("o_custkey").agg(c=("*", "count")),
    }


def run_baseline(db: VerticaDB, q: LogicalQuery,
                 raw: Dict[str, jnp.ndarray]):
    """C-Store-prototype-era execution: full uncompressed scans, no
    pruning/SIP; sort-based groupby. Same device (jnp), same results."""
    from repro.engine import operators as ops
    valid = jnp.ones(raw["l_shipdate"].shape[0], bool)
    if q.predicate is not None:
        valid = valid & jnp.asarray(q.predicate(raw), bool)
    cols = dict(raw)
    for spec in q.joins:
        dim = db.read_table(spec.dim_table)
        if spec.dim_predicate is not None:
            m = np.asarray(spec.dim_predicate(dim), bool)
            dim = {c: v[m] for c, v in dim.items()}
        build = {c: jnp.asarray(dim[c])
                 for c in (spec.dim_key,) + tuple(spec.dim_columns)}
        cols, valid = ops.hash_join(build, spec.dim_key, cols,
                                    spec.fact_key, valid, how=spec.how)
    aggs = tuple(q.aggs)
    values = {c: cols[c] for _, c, kind in aggs
              if kind != "count" and c != "*"}
    if not q.group_by:
        keys = jnp.zeros(valid.shape[0], jnp.int32)
        return ops.groupby_dense(keys, valid, values, 1, aggs)
    assert len(q.group_by) == 1, "baseline models the 1-key prototype"
    return ops.groupby_sort(cols[q.group_by[0]], valid, values,
                            1 << 16, aggs)


def _time(fn, reps=3):
    fn()  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0]) if out else None
        ts.append(time.time() - t0)
    return min(ts)


# the single-table segmented subset of the workload (joins go through
# the same executor but their dim placement cost is covered by the
# differential tests; this tracks the scan->exchange->aggregate spine)
SEG_NAMES = ("Q2", "Q3", "Q4", "Q6")


def _run_mesh8():
    """Subprocess entry (``--mesh8``): re-run the segmented subset on a
    forced 8-device host mesh and print one JSON line.  Device count is
    fixed at process start, so the scale-out point needs its own
    process; the parent treats any failure as 'skipped'."""
    import json
    n_fact = QUICK_N_FACT if _quick() else N_FACT
    n_dim = QUICK_N_DIM if _quick() else N_DIM
    db = build_db(n_fact, n_dim)
    queries = {n: qb.to_ir() for n, qb in make_builders(db).items()}
    single = sum(_time(lambda q=queries[n]: execute(db, q)[0])
                 for n in SEG_NAMES)
    mesh = db.attach_mesh()
    n_shards = int(mesh.shape["data"])
    seg = 0.0
    seg_all = True
    for name in SEG_NAMES:
        last = {}

        def run_seg(q=queries[name], last=last):
            out, st = execute(db, q)
            last["stats"] = st
            return out
        seg += _time(run_seg)
        seg_all &= last["stats"].segmented
    # per-stage wall clocks (ExecStats.stage_ms): one extra warm pass per
    # query with stage syncs enabled -- the timed loop above stays
    # sync-free so stage accounting never distorts the headline number
    stage_ms = {}
    db.collect_stage_timing = True
    for name in SEG_NAMES:
        _, st = execute(db, queries[name])
        for k, v in st.stage_ms.items():
            stage_ms[k] = stage_ms.get(k, 0.0) + v
    db.collect_stage_timing = False
    db.detach_mesh()
    print(json.dumps({
        "n_shards": n_shards, "n_fact": n_fact,
        "segmented_s": seg, "single_node_s": single,
        "speedup_vs_single_node": single / seg,
        "stage_ms": {k: round(v, 2) for k, v in stage_ms.items()},
        "all_segmented": bool(seg_all)}))


def _mesh8_row(timeout_s: int = 2400):
    """The 8-device mesh tier of the segmented bench, via subprocess
    (XLA device count is a process-start flag).  Never breaks the main
    bench: any failure or REPRO_BENCH_SKIP_MESH8=1 records a skip."""
    import json
    import subprocess
    if os.environ.get("REPRO_BENCH_SKIP_MESH8", "") == "1":
        return {"skipped": "REPRO_BENCH_SKIP_MESH8=1"}
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    try:
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--mesh8"],
            env=env, capture_output=True, text=True, timeout=timeout_s,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        line = [ln for ln in proc.stdout.strip().splitlines()
                if ln.startswith("{")][-1]
        return json.loads(line)
    except Exception as e:                        # noqa: BLE001
        return {"skipped": f"{type(e).__name__}: {e}"[:200]}


# the predicate subset of the workload used by the compression tier
# (each is eligible for the code-domain scan: int interval predicates)
COMP_NAMES = ("Q1", "Q2", "Q3", "Q6")


def _bench_compression(db: VerticaDB, queries: Dict[str, LogicalQuery]):
    """Compression tier (DESIGN.md §9), three claims PR-over-PR:

      packed_ratio              -- real packed device bytes / decoded
                                   int32 lanes for the workload's columns
                                   (actual buffer sizes, not a model)
      constrained_cache_speedup -- warm total under a cache budget that
                                   holds the packed working set but NOT
                                   the decoded one: packed-resident
                                   compressed execution vs the decoded-
                                   resident baseline at the SAME budget
      unconstrained_warm_ratio  -- auto mode / forced-decoded mode with
                                   an ample budget (the warm fast path
                                   must not pay for the compressed
                                   machinery it does not use)
    """
    from repro.core.block_cache import BlockCache
    from repro.core.encodings import device_bytes

    need = ("l_shipdate", "l_suppkey", "l_qty", "l_extprice")
    packed = decoded = 0
    for node in db.nodes:
        st = node.stores.get("lineitem_super")
        if st is None:
            continue
        for c in st.containers:
            for name in need:
                ec = c.columns[name]
                inner = ec.inner if ec.inner is not None else ec
                packed += device_bytes(inner.arrays)
                decoded += inner.n_blocks * inner.block_rows * 4
    # a budget that fits the packed working set with headroom but not the
    # decoded one: the decoded-resident baseline must thrash, the packed-
    # resident compressed path must stay warm
    budget = max(int(0.55 * (packed + decoded)), 2 * packed + (1 << 20))
    saved_cache, saved_mode = db.block_cache, db.exec_mode

    def _warm_total(mode, cache):
        db.block_cache = cache
        db.exec_mode = mode
        return sum(_time(lambda q=queries[n]: execute(db, q)[0])
                   for n in COMP_NAMES)

    try:
        t_dec_c = _warm_total(
            "decoded", BlockCache(budget, protect_packed=False))
        t_pack_c = _warm_total(
            "compressed", BlockCache(budget, protect_packed=True))
        # unconstrained: ample budget, both modes fully warm
        t_dec_u = _warm_total("decoded", BlockCache(1 << 30))
        db.exec_mode = "auto"
        t_auto_u = sum(_time(lambda q=queries[n]: execute(db, q)[0])
                       for n in COMP_NAMES)
    finally:
        db.block_cache, db.exec_mode = saved_cache, saved_mode
    return {
        "queries": list(COMP_NAMES),
        "packed_mb": packed / 1e6, "decoded_mb": decoded / 1e6,
        "packed_ratio": packed / decoded if decoded else 0.0,
        "budget_mb": budget / 1e6,
        "constrained_decoded_s": t_dec_c,
        "constrained_packed_s": t_pack_c,
        "constrained_cache_speedup": t_dec_c / t_pack_c,
        "unconstrained_decoded_s": t_dec_u,
        "unconstrained_auto_s": t_auto_u,
        "unconstrained_warm_ratio": t_auto_u / t_dec_u,
    }


# fixed small size: the failover bench measures the retry/replan
# machinery and buddy routing, not scan throughput, so it does not
# scale with --quick
FAILOVER_N_FACT = 80_000


def _bench_failover():
    fact, _ = star_schema(FAILOVER_N_FACT, 2_000)
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=4096)
    db.create_table(TableSchema("lineitem", (
        ColumnDef("l_orderkey"), ColumnDef("l_suppkey"),
        ColumnDef("l_shipdate"), ColumnDef("l_qty"),
        ColumnDef("l_extprice", SQLType.FLOAT))),
        sort_order=("l_shipdate", "l_suppkey"),
        segment_by=("l_orderkey",))
    t = db.begin()
    db.insert(t, "lineitem", fact)
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    db.attach_mesh()
    try:
        q = (db.query("lineitem").group_by("l_suppkey")
             .agg(c=("*", "count"), s=("l_qty", "sum")).to_ir())
        healthy = _time(lambda: execute(db, q)[0])

        # one-shot: node 1 dies mid-scan, the query replans onto buddies
        # at its pinned epoch and still answers (includes the wasted
        # attempt + replan, i.e. the latency a client actually sees)
        inj = db.enable_faults(seed=7)
        inj.on("segmented.slab_build", CrashNode(), node=1, hit=1)
        t0 = time.time()
        out, stats = execute(db, q)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        failover_s = time.time() - t0
        db.disable_faults()
        assert stats.failovers >= 1 and not db.nodes[1].up

        # steady-state degraded: node 1 still down, segment 1 served by
        # its buddy copy on node 2 (cold slab rebuild happens in warmup)
        degraded = _time(lambda: execute(db, q)[0])
    finally:
        db.detach_mesh()
    return {"n_fact": FAILOVER_N_FACT,
            "healthy_warm_ms": healthy * 1e3,
            "failover_query_ms": failover_s * 1e3,
            "degraded_warm_ms": degraded * 1e3,
            "degraded_over_healthy": degraded / healthy,
            "failovers": stats.failovers,
            "fault_retries": stats.fault_retries}


def run(report):
    from repro.planner import plan_query

    n_fact = QUICK_N_FACT if _quick() else N_FACT
    n_dim = QUICK_N_DIM if _quick() else N_DIM
    db = build_db(n_fact, n_dim)
    raw_np = db.read_table("lineitem")
    raw = {k: jnp.asarray(v) for k, v in raw_np.items()}
    rep = db.storage_report()["lineitem_super"]

    builders = make_builders(db)
    QUERIES = {name: qb.to_ir() for name, qb in builders.items()}

    # --- front-end overhead: builder lowering + planning, standalone ---
    frontend = {}
    for name, qb in builders.items():
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            plan_query(db, qb.to_ir())
        frontend[name] = (time.time() - t0) / reps

    # --- cold pass: first-ever run of each query (upload + decode +
    # trace/compile + execute), empty block & plan caches ---
    from repro.engine import PLAN_CACHE
    PLAN_CACHE.clear()
    db.block_cache.clear()
    cold = {}
    for name, q in QUERIES.items():
        t0 = time.time()
        out = execute(db, q)[0]
        jax.block_until_ready(jax.tree.leaves(out)[0]) if out else None
        cold[name] = time.time() - t0

    paper = {"Q1": (30, 14), "Q2": (360, 71), "Q3": (4900, 4833),
             "Q4": (2090, 280), "Q5": (310, 93), "Q6": (8500, 4143),
             "Q7": (2540, 161)}
    rows = {}
    tot_v = tot_b = tot_cold = tot_fe = 0.0
    for name, q in QUERIES.items():
        tv = _time(lambda q=q: execute(db, q)[0])
        tb = _time(lambda q=q: run_baseline(db, q, raw))
        out_v, stats = execute(db, q)
        rows[name] = {"vertica_ms": tv * 1e3, "baseline_ms": tb * 1e3,
                      "cold_ms": cold[name] * 1e3,
                      "frontend_ms": frontend[name] * 1e3,
                      "warm_over_cold": tv / cold[name],
                      "speedup": tb / tv,
                      "plan": {"projection": stats.projection,
                               "groupby": stats.groupby_algorithm,
                               "fused": stats.fused,
                               "plan_cache": stats.plan_cache,
                               "block_cache": f"{stats.block_cache_hits}h/"
                                              f"{stats.block_cache_misses}m",
                               "pruned": f"{stats.blocks_pruned}/"
                                         f"{stats.blocks_total}"},
                      "paper_cstore_ms": paper[name][0],
                      "paper_vertica_ms": paper[name][1]}
        tot_v += tv
        tot_b += tb
        tot_cold += cold[name]
        tot_fe += frontend[name]
        print(f"[cstore] {name}: cold {cold[name]*1e3:8.1f}ms  "
              f"warm {tv*1e3:8.1f}ms  baseline {tb*1e3:8.1f}ms  "
              f"frontend {frontend[name]*1e3:6.2f}ms  "
              f"speedup {tb/tv:5.2f}x  cache "
              f"{stats.block_cache_hits}h/{stats.block_cache_misses}m  "
              f"pruned {stats.blocks_pruned}/{stats.blocks_total}")
    # --- segmented-vs-single-node: the same warm queries routed through
    # the multi-device executor (engine/segmented.py); on a 1-device CPU
    # run this measures pure segmentation overhead, on N devices the
    # scale-out win.  Recorded into BENCH_cstore.json PR-over-PR. ---
    seg_names = SEG_NAMES
    mesh = db.attach_mesh()
    n_shards = int(mesh.shape["data"])
    seg_total = 0.0
    seg_all = True
    for name in seg_names:
        q = QUERIES[name]
        last = {}

        def run_seg(q=q, last=last):
            out, st = execute(db, q)
            last["stats"] = st
            return out
        ts = _time(run_seg)
        seg_all &= last["stats"].segmented
        seg_total += ts
    db.detach_mesh()
    single_total = sum(rows[n]["vertica_ms"] for n in seg_names) / 1e3
    seg_row = {"n_shards": n_shards, "queries": list(seg_names),
               "segmented_s": seg_total, "single_node_s": single_total,
               "speedup_vs_single_node": single_total / seg_total,
               "all_segmented": bool(seg_all)}
    print(f"[cstore] segmented ({n_shards} shard(s)): "
          f"{seg_total*1e3:.1f}ms vs single-node "
          f"{single_total*1e3:.1f}ms = "
          f"{single_total/seg_total:.2f}x over {list(seg_names)}")
    # scale-out point: same subset on a forced 8-device host mesh (its
    # own process; XLA fixes device count at start).  Records BOTH the
    # 1-shard overhead ratio above and the mesh-tier ratio PR-over-PR.
    seg_row["mesh8"] = _mesh8_row()
    m8 = seg_row["mesh8"]
    if "skipped" in m8:
        print(f"[cstore] segmented mesh8: skipped ({m8['skipped']})")
    else:
        print(f"[cstore] segmented mesh8 ({m8['n_shards']} shards): "
              f"{m8['segmented_s']*1e3:.1f}ms vs single-node "
              f"{m8['single_node_s']*1e3:.1f}ms = "
              f"{m8['speedup_vs_single_node']:.2f}x")

    # --- compression tier (DESIGN.md §9): real packed footprint + the
    # constrained-cache experiment (packed-resident compressed execution
    # vs the decoded-resident baseline at the same byte budget) ---
    comp_row = _bench_compression(db, QUERIES)
    print(f"[cstore] compression: packed {comp_row['packed_mb']:.1f}MB / "
          f"decoded {comp_row['decoded_mb']:.1f}MB = "
          f"{comp_row['packed_ratio']:.2f}x; constrained cache "
          f"({comp_row['budget_mb']:.1f}MB): compressed "
          f"{comp_row['constrained_packed_s']*1e3:.1f}ms vs decoded "
          f"{comp_row['constrained_decoded_s']*1e3:.1f}ms = "
          f"{comp_row['constrained_cache_speedup']:.2f}x; unconstrained "
          f"warm ratio {comp_row['unconstrained_warm_ratio']:.2f}x")

    # --- failover overhead (K-safety, §4.3): warm latency on a healthy
    # cluster vs the one-shot mid-query failover (node crash + replan
    # onto buddies at the pinned epoch) vs warm steady-state with the
    # node still down (buddy routing).  Small fixed size: this measures
    # the RETRY machinery, not scan throughput. ---
    failover_row = _bench_failover()
    print(f"[cstore] failover: healthy {failover_row['healthy_warm_ms']:.1f}ms, "
          f"mid-query crash+retry {failover_row['failover_query_ms']:.1f}ms "
          f"({failover_row['failovers']} failover(s)), degraded warm "
          f"{failover_row['degraded_warm_ms']:.1f}ms "
          f"({failover_row['degraded_over_healthy']:.2f}x)")

    result = {
        "n_fact": n_fact, "quick": _quick(), "queries": rows,
        "segmented": seg_row, "failover": failover_row,
        "compression": comp_row,
        "total_vertica_s": tot_v, "total_baseline_s": tot_b,
        "total_cold_s": tot_cold, "total_warm_s": tot_v,
        "total_frontend_s": tot_fe,
        "warm_speedup_vs_cold": tot_cold / tot_v,
        "total_speedup": tot_b / tot_v,
        "disk_encoded_mb": rep["stored_bytes"] / 1e6,
        "disk_raw_mb": rep["raw_bytes"] / 1e6,
        "disk_ratio": rep["ratio"],
        "paper": {"total_cstore_s": 18.7, "total_vertica_s": 9.6,
                  "total_speedup": 1.95, "disk_cstore_mb": 1987,
                  "disk_vertica_mb": 949, "disk_ratio": 2.09},
    }
    print(f"[cstore] TOTAL: cold {tot_cold:.2f}s warm {tot_v:.2f}s "
          f"(warm {tot_cold/tot_v:.1f}x faster) baseline {tot_b:.2f}s "
          f"speedup {tot_b/tot_v:.2f}x (paper: 1.95x); frontend "
          f"{tot_fe*1e3:.1f}ms total; disk "
          f"{rep['stored_bytes']/1e6:.0f}MB vs raw "
          f"{rep['raw_bytes']/1e6:.0f}MB = {rep['ratio']:.1f}x "
          f"(paper: 2.1x)")
    report("cstore_queries/table3", result)


if __name__ == "__main__":
    if "--mesh8" in sys.argv:
        _run_mesh8()
    else:
        run(lambda k, v: None)
