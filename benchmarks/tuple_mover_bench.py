"""Tuple mover behaviour under sustained ingest (paper §4): container-count
stability (no explosion), bounded re-merges, ingest rate, and compression
improving as containers merge into larger sorted runs."""
from __future__ import annotations

import math
import sys
import time
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB  # noqa


def run(report):
    rng = np.random.default_rng(0)
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=4096)
    db.create_table(TableSchema("events", (
        ColumnDef("ts"), ColumnDef("kind"),
        ColumnDef("value", SQLType.FLOAT))),
        sort_order=("kind", "ts"), segment_by=("ts",))

    waves = 24
    rows_per_wave = 25_000
    t0 = time.time()
    timeline = []
    total_merges = 0
    for w in range(waves):
        t = db.begin()
        db.insert(t, "events", {
            "ts": np.sort(rng.integers(w * 10**6, (w + 1) * 10**6,
                                       rows_per_wave)),
            "kind": rng.integers(0, 8, rows_per_wave),
            "value": rng.normal(size=rows_per_wave)})
        db.commit(t)
        stats = db.run_tuple_mover(force_moveout=True)
        total_merges += stats["mergeouts"]
        rep = db.storage_report()["events_super"]
        timeline.append({"wave": w, "containers": rep["containers"],
                         "ratio": round(rep["ratio"], 2),
                         "mergeouts": stats["mergeouts"]})
    dt = time.time() - t0
    n_total = waves * rows_per_wave
    max_containers = max(t_["containers"] for t_ in timeline)
    # bound: merges per tuple is O(log waves)
    merge_bound = waves * math.ceil(math.log2(waves) + 1)
    result = {
        "rows_ingested": n_total,
        "ingest_rows_per_s": n_total / dt,
        "final_containers": timeline[-1]["containers"],
        "max_containers": max_containers,
        "total_mergeouts": total_merges,
        "merge_bound": merge_bound,
        "final_compression": timeline[-1]["ratio"],
        "timeline": timeline[::4],
    }
    print(f"[tuple_mover] {n_total:,} rows at "
          f"{n_total/dt:,.0f} rows/s; containers max {max_containers} "
          f"final {timeline[-1]['containers']}; mergeouts {total_merges} "
          f"(bound {merge_bound}); compression "
          f"{timeline[-1]['ratio']:.2f}x")
    assert total_merges <= merge_bound
    report("tuple_mover/ingest", result)


if __name__ == "__main__":
    run(lambda k, v: None)
