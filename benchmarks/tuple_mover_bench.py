"""Tuple mover behaviour under sustained ingest (paper §4): container-count
stability (no explosion), bounded re-merges, ingest rate, and compression
improving as containers merge into larger sorted runs.

Also measures epoch-history compaction (paper §5.1): the AHM trails every
commit by construction, a pinned query snapshot stalls it (history a live
snapshot reads cannot be purged), and it catches back up to the commit
frontier once the pin is released. The pin window here models a
long-running report holding a snapshot mid-ingest."""
from __future__ import annotations

import math
import sys
import time
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB  # noqa


def run(report):
    rng = np.random.default_rng(0)
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=4096)
    db.create_table(TableSchema("events", (
        ColumnDef("ts"), ColumnDef("kind"),
        ColumnDef("value", SQLType.FLOAT))),
        sort_order=("kind", "ts"), segment_by=("ts",))

    waves = 24
    rows_per_wave = 25_000
    pin_wave, unpin_wave = 8, 16
    pinned_epoch = None
    t0 = time.time()
    timeline = []
    total_merges = 0
    for w in range(waves):
        if w == pin_wave:
            pinned_epoch = db.epochs.pin()
            ahm_at_pin = db.epochs.ahm
        if w == unpin_wave:
            db.epochs.unpin(pinned_epoch)
        t = db.begin()
        db.insert(t, "events", {
            "ts": np.sort(rng.integers(w * 10**6, (w + 1) * 10**6,
                                       rows_per_wave)),
            "kind": rng.integers(0, 8, rows_per_wave),
            "value": rng.normal(size=rows_per_wave)})
        db.commit(t)
        stats = db.run_tuple_mover(force_moveout=True)
        total_merges += stats["mergeouts"]
        rep = db.storage_report()["events_super"]
        timeline.append({"wave": w, "containers": rep["containers"],
                         "ratio": round(rep["ratio"], 2),
                         "mergeouts": stats["mergeouts"],
                         "ahm": db.epochs.ahm,
                         "epoch_span": db.epochs.latest_queryable()
                         - db.epochs.ahm})
    dt = time.time() - t0
    n_total = waves * rows_per_wave
    max_containers = max(t_["containers"] for t_ in timeline)
    # bound: merges per tuple is O(log waves)
    merge_bound = waves * math.ceil(math.log2(waves) + 1)
    pinned_window = timeline[pin_wave:unpin_wave]
    max_span_pinned = max(t_["epoch_span"] for t_ in pinned_window)
    result = {
        "rows_ingested": n_total,
        "ingest_rows_per_s": n_total / dt,
        "final_containers": timeline[-1]["containers"],
        "max_containers": max_containers,
        "total_mergeouts": total_merges,
        "merge_bound": merge_bound,
        "final_compression": timeline[-1]["ratio"],
        "pinned_epoch": pinned_epoch,
        "max_epoch_span_pinned": max_span_pinned,
        "ahm_final": timeline[-1]["ahm"],
        "epoch_span_final": timeline[-1]["epoch_span"],
        "timeline": timeline[::4],
    }
    print(f"[tuple_mover] {n_total:,} rows at "
          f"{n_total/dt:,.0f} rows/s; containers max {max_containers} "
          f"final {timeline[-1]['containers']}; mergeouts {total_merges} "
          f"(bound {merge_bound}); compression "
          f"{timeline[-1]['ratio']:.2f}x; AHM span while pinned "
          f"{max_span_pinned}, final {timeline[-1]['epoch_span']}")
    assert total_merges <= merge_bound
    # the pinned snapshot stalls the AHM at its pin-time value for the
    # whole window (8 waves of ingest advance the commit frontier but
    # none of that history may be purged)...
    assert all(t_["ahm"] == ahm_at_pin for t_ in pinned_window)
    # ...and once unpinned the AHM catches back up past the pin point
    assert timeline[-1]["ahm"] > pinned_epoch
    assert timeline[-1]["epoch_span"] <= pinned_window[0]["epoch_span"]
    report("tuple_mover/ingest", result)


if __name__ == "__main__":
    run(lambda k, v: None)
