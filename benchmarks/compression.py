"""Table 4 reproduction: compression on 1M random integers and the
customer meter data (paper §8.2).

Baselines are REAL: gzip = zlib level 6 on the same text bytes the paper
describes; 'Vertica' = our AUTO-encoded storage_bytes after sorting by the
projection order (metric, meter, ts), exactly the paper's setup. The meter
workload regenerates the published shape (a few hundred metrics, a couple
thousand meters, periodic timestamps, trending/zero/noisy values) at a
CPU-friendly scale; bytes/row is scale-free.
"""
from __future__ import annotations

import sys
import time
import zlib
from typing import Dict

import numpy as np

sys.path.insert(0, "src")

from repro.core.encodings import Encoding, encode  # noqa: E402
from repro.core.types import SQLType  # noqa: E402
from repro.data.synth import meter_data  # noqa: E402


def bench_random_integers(n: int = 1_000_000, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    v = rng.integers(1, 10_000_001, n).astype(np.int64)
    text = b"\n".join(str(x).encode() for x in v[:200_000])
    scale = n / 200_000
    raw_bytes = len(text) * scale + scale  # extrapolate text size
    gz = len(zlib.compress(text, 6)) * scale
    vs = np.sort(v)
    text_sorted = b"\n".join(str(x).encode() for x in vs[:200_000])
    gz_sorted = len(zlib.compress(text_sorted, 6)) * scale
    enc = encode(vs, SQLType.INT, Encoding.AUTO, block_rows=4096)
    rows = {
        "raw": raw_bytes,
        "gzip": gz,
        "gzip+sort": gz_sorted,
        "vertica": enc.storage_bytes(),
    }
    return {
        "name": "1M random integers (paper Table 4 top)",
        "n_rows": n,
        "bytes": rows,
        "bytes_per_row": {k: v / n for k, v in rows.items()},
        "ratio_vs_raw": {k: raw_bytes / v for k, v in rows.items()},
        "encoding_chosen": enc.encoding.value,
        "paper": {"raw_mb": 7.5, "gzip_ratio": 2.1, "gzip_sort_ratio": 3.3,
                  "vertica_ratio": 12.5, "vertica_bpr": 0.6},
    }


def bench_meter_data(n: int = 2_000_000, seed: int = 0) -> Dict:
    data = meter_data(n, seed)
    n = len(data["metric"])
    # sort by (metric, meter, ts) -- the paper's projection order
    order = np.lexsort((data["ts"], data["meter"], data["metric"]))
    data = {k: v[order] for k, v in data.items()}
    # raw CSV bytes (sampled then extrapolated)
    m = min(n, 100_000)
    lines = b"\n".join(
        f"{data['metric'][i]},{data['meter'][i]},{data['ts'][i]},"
        f"{data['value'][i]}".encode() for i in range(m))
    csv_bytes = len(lines) * (n / m)
    gz_bytes = len(zlib.compress(lines, 6)) * (n / m)
    per_col = {}
    total = 0.0
    for colname, typ in (("metric", SQLType.INT), ("meter", SQLType.INT),
                         ("ts", SQLType.INT), ("value", SQLType.FLOAT)):
        enc = encode(data[colname], typ, Encoding.AUTO, block_rows=4096)
        per_col[colname] = {"bytes": enc.storage_bytes(),
                            "encoding": enc.encoding.value}
        total += enc.storage_bytes()
    return {
        "name": "customer meter data (paper Table 4 bottom)",
        "n_rows": n,
        "bytes": {"raw_csv": csv_bytes, "gzip": gz_bytes, "vertica": total},
        "bytes_per_row": {"raw_csv": csv_bytes / n, "gzip": gz_bytes / n,
                          "vertica": total / n},
        "ratio_vs_raw": {"gzip": csv_bytes / gz_bytes,
                         "vertica": csv_bytes / total},
        "per_column": per_col,
        "paper": {"raw_bpr": 32.5, "gzip_bpr": 5.5, "vertica_bpr": 2.2,
                  "gzip_ratio": 5.9, "vertica_ratio": 14.8},
    }


def run(report):
    t0 = time.time()
    r1 = bench_random_integers()
    report("compression/1M_random_ints", r1)
    r2 = bench_meter_data()
    report("compression/meter_data", r2)
    print(f"[compression] 1M ints: vertica {r1['ratio_vs_raw']['vertica']:.1f}x"
          f" (paper 12.5x), {r1['bytes_per_row']['vertica']:.2f} B/row "
          f"(paper 0.6); gzip {r1['ratio_vs_raw']['gzip']:.1f}x (paper 2.1)")
    print(f"[compression] meter: vertica {r2['ratio_vs_raw']['vertica']:.1f}x"
          f" (paper 14.8x), {r2['bytes_per_row']['vertica']:.2f} B/row "
          f"(paper 2.2); gzip {r2['ratio_vs_raw']['gzip']:.1f}x (paper 5.9)")
    print(f"[compression] done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    run(lambda k, v: None)
