"""Closed-loop serving benchmark (paper §7 workload management).

N clients drive the serving front door (engine/serving.py) in a closed
loop -- each client submits its next query only after its previous one
completed -- so the latency a ticket observes includes real queue wait.
The same per-client schedules then run serially, one query at a time
through the ordinary pipeline, as the baseline the overlapped path must
beat: a coalesced group assembles its (cache-resident) scan once where
serial execution assembles it once PER QUERY, and the pipelined
dispatch/drain core overlaps one unit's device compute with the next
unit's host-side planning and scan assembly.

A second phase measures interactive isolation: the p99 of a fixed
interactive probe, unloaded and then under a batch flood bounded by the
batch bulkhead -- the ratio is the paper's "web-scale traffic must not
starve the dashboard" claim in one number.

Reports p50/p95/p99 latency, throughput, shared-scan hit rate, speedup
over serial, and the flood ratio; benchmarks/run.py writes the result to
repo-root BENCH_serving.json so tail latency is tracked PR-over-PR
(scripts/verify.sh gates on regressions).
"""
from __future__ import annotations

import os
import sys
import time
from typing import List

import numpy as np

sys.path.insert(0, "src")

from repro.core import (ColumnDef, QueryRejectedError, SQLType,  # noqa: E402
                        TableSchema, VerticaDB)
from repro.engine import col, execute  # noqa: E402

N_FACT = 400_000
N_WAVES = 12           # ROS containers per store: real scan-assembly work
N_CLIENTS = 12
OPS_PER_CLIENT = 12
QUICK_N_FACT = 60_000
QUICK_N_WAVES = 6
QUICK_N_CLIENTS = 6
QUICK_OPS = 6
N_CIDS = 64


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "") == "1"


def _build_db(n_fact: int, waves: int) -> VerticaDB:
    rng = np.random.default_rng(0)
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=512)
    db.create_table(
        TableSchema("sales", (ColumnDef("sale_id"), ColumnDef("cid"),
                              ColumnDef("day"), ColumnDef("qty"),
                              ColumnDef("price", SQLType.FLOAT))),
        sort_order=("day",), segment_by=("sale_id",))
    per = n_fact // waves
    for w in range(waves):
        t = db.begin()
        db.insert(t, "sales", {
            "sale_id": np.arange(w * per, (w + 1) * per),
            "cid": rng.integers(0, N_CIDS, per),
            "day": np.sort(rng.integers(0, 365, per)),
            "qty": rng.integers(1, 10, per),
            "price": rng.integers(40, 4000, per).astype(np.float64) / 4})
        db.commit(t)
        # moveout only: keep one container per wave so the scan has many
        # containers to assemble (the cost coalescing amortizes)
        db.run_tuple_mover(force_moveout=True, do_mergeout=False)
    return db


def _mix(db) -> List:
    """The query mix: single-table aggregate shapes that coalesce.
    Predicates avoid the sort leader so SMA pruning doesn't hand the
    serial baseline a different (smaller) scan than the shared one."""
    q = db.query
    return [
        q("sales").group_by("cid").agg(n=("*", "count")).to_ir(),
        q("sales").group_by("cid").agg(rev=("price", "sum")).to_ir(),
        q("sales").where(col("qty") > 5).group_by("cid")
        .agg(s=("price", "sum"), n=("*", "count")).to_ir(),
        q("sales").where(col("cid") < N_CIDS // 2).group_by("qty")
        .agg(avg_p=("price", "avg")).to_ir(),
        q("sales").agg(total=("price", "sum"), n=("*", "count")).to_ir(),
        q("sales").where(col("qty") == 3).agg(n=("*", "count")).to_ir(),
        q("sales").group_by("qty").agg(mx=("price", "max"),
                                       mn=("price", "min")).to_ir(),
        q("sales").select(margin=col("price") * col("qty"))
        .group_by("cid").agg(m=("margin", "sum")).order_by("-m")
        .limit(10).to_ir(),
    ]


def _percentiles(lat_ms: List[float]):
    a = np.asarray(sorted(lat_ms))
    return (float(np.percentile(a, 50)), float(np.percentile(a, 95)),
            float(np.percentile(a, 99)))


def run(report):
    quick = _quick()
    n_fact = QUICK_N_FACT if quick else N_FACT
    waves = QUICK_N_WAVES if quick else N_WAVES
    n_clients = QUICK_N_CLIENTS if quick else N_CLIENTS
    ops = QUICK_OPS if quick else OPS_PER_CLIENT

    db = _build_db(n_fact, waves)
    mix = _mix(db)
    rng = np.random.default_rng(42)
    scripts = [[mix[i] for i in rng.integers(0, len(mix), ops)]
               for _ in range(n_clients)]

    # warm both paths outside the timed windows: plan-cache + block-cache
    # entries for the dedicated programs (serial) and shared programs
    for q in mix:
        execute(db, q)
    warm = db.serve(queue_depth=len(mix) + 1, max_coalesce=len(mix))
    for q in mix:
        warm.submit(q)
    warm.drain()

    # --- serial baseline: the same ops one at a time ---
    t0 = time.time()
    serial_lat = []
    for rnd in range(ops):
        for ci in range(n_clients):
            t1 = time.time()
            execute(db, scripts[ci][rnd])
            serial_lat.append((time.time() - t1) * 1000)
    serial_s = time.time() - t0

    # --- closed-loop serving run ---
    svc = db.serve(queue_depth=n_clients + 2, max_concurrent=4,
                   max_coalesce=8, batch_boost_after=4)
    sessions = [svc.session("interactive" if ci % 3 else "batch")
                for ci in range(n_clients)]
    next_op = [0] * n_clients
    inflight = {}
    lat_ms: List[float] = []
    waits: List[float] = []
    rejected = 0
    t0 = time.time()
    while True:
        for ci, sess in enumerate(sessions):
            if ci in inflight or next_op[ci] >= ops:
                continue
            try:
                inflight[ci] = sess.submit(scripts[ci][next_op[ci]])
            except QueryRejectedError:
                rejected += 1
            next_op[ci] += 1
        if not inflight:
            if all(n >= ops for n in next_op):
                break
            continue
        svc.step()
        for ci in [c for c, t in inflight.items() if t.done]:
            t = inflight.pop(ci)
            if t.state == "done":
                lat_ms.append(t.stats.total_s * 1000)
                waits.append(t.stats.queue_wait_s * 1000)
    serving_s = time.time() - t0

    # --- interactive isolation: fixed probe, unloaded vs batch flood ---
    flood_n = 24 if quick else 48
    n_probe = 12 if quick else 24
    probe = mix[0]
    svc2 = db.serve(queue_depth=flood_n + 8, max_concurrent=4,
                    max_coalesce=8,
                    max_in_flight={"interactive": 4, "batch": 2})
    inter = svc2.session("interactive")
    unloaded_ms: List[float] = []
    for _ in range(n_probe):
        t1 = time.time()
        inter.submit(probe).result()
        unloaded_ms.append((time.time() - t1) * 1000)
    batch_sess = svc2.session("batch")
    flood = [batch_sess.submit(mix[int(rng.integers(0, len(mix)))])
             for _ in range(flood_n)]
    flooded_ms: List[float] = []
    for _ in range(n_probe):
        svc2.step()            # the flood occupies the service between
        svc2.step()            # probes: batch units dispatch + park
        t1 = time.time()
        inter.submit(probe).result()
        flooded_ms.append((time.time() - t1) * 1000)
    svc2.drain()
    assert all(t.done for t in flood)
    p99_unloaded = float(np.percentile(np.asarray(unloaded_ms), 99))
    p99_flood = float(np.percentile(np.asarray(flooded_ms), 99))
    flood_ratio = p99_flood / p99_unloaded if p99_unloaded else 0.0

    p50, p95, p99 = _percentiles(lat_ms)
    sp50, sp95, sp99 = _percentiles(serial_lat)
    n_ok = len(lat_ms)
    result = {
        "quick": quick,
        "n_fact": n_fact,
        "ros_containers_per_store": waves,
        "clients": n_clients,
        "ops_total": n_clients * ops,
        "completed": n_ok,
        "rejected": rejected,
        "p50_ms": round(p50, 3),
        "p95_ms": round(p95, 3),
        "p99_ms": round(p99, 3),
        "serial_p50_ms": round(sp50, 3),
        "serial_p99_ms": round(sp99, 3),
        "mean_queue_wait_ms": round(float(np.mean(waits)), 3) if waits
        else 0.0,
        "throughput_qps": round(n_ok / serving_s, 2),
        "serial_qps": round(len(serial_lat) / serial_s, 2),
        "speedup_vs_serial": round(serial_s / serving_s, 3),
        "shared_scan_hit_rate": round(svc.stats.shared_hit_rate(), 3),
        "shared_scans": svc.stats.shared_scans,
        "coalesced_max": svc.stats.coalesced_max,
        "batch_boosts": svc.stats.batch_boosts,
        "async_units": svc.stats.async_units,
        "deduped": svc.stats.deduped,
        "device_transfers": svc.stats.device_transfers,
        "interactive_p99_unloaded_ms": round(p99_unloaded, 3),
        "interactive_p99_flood_ms": round(p99_flood, 3),
        "interactive_p99_flood_ratio": round(flood_ratio, 3),
        "flood_batch_peak_in_flight": svc2.stats.peak_in_flight.get(
            "batch", 0),
        "peak_reserved_mb": round(
            db.block_cache.stats.peak_reserved_bytes / 2**20, 1),
    }
    print(f"[serving] {n_ok}/{n_clients * ops} ops, {n_clients} clients | "
          f"p50 {p50:.1f}ms p95 {p95:.1f}ms p99 {p99:.1f}ms | "
          f"{result['throughput_qps']} qps vs serial "
          f"{result['serial_qps']} qps "
          f"(speedup {result['speedup_vs_serial']}x) | "
          f"shared-scan hit rate {result['shared_scan_hit_rate']:.0%} "
          f"(max group {svc.stats.coalesced_max}) | "
          f"flood p99 ratio {flood_ratio:.2f}x "
          f"({p99_flood:.1f}ms vs {p99_unloaded:.1f}ms unloaded)")
    assert svc.stats.shared_hit_rate() > 0, "no query rode a shared scan"
    assert svc.stats.async_units > 0, "nothing dispatched asynchronously"
    assert db.epochs.n_pinned() == 0, "serving leaked an epoch pin"
    report("serving/closed_loop", result)


if __name__ == "__main__":
    run(lambda k, v: None)
